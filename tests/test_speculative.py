"""Cross-arch speculative parity suite.

The contract under test: speculative ``decode`` (sessions/spec.py, exact
``verify="scan"`` mode) emits a token stream BIT-IDENTICAL to plain greedy
``LMSessionService.decode`` for ANY drafter — always-right, always-wrong,
random garbage, truncated — across the GQA, MLA, RWKV, and SSM(hybrid)
bundles, through arbitrary decode splits and evict→park→resume churn
mid-draft (including a disk spill into a fresh service).  The drafter is
advisory: it can only change HOW FAST the stream is produced, never what
the stream is.

``verify="parallel"`` (the throughput mode, pure-KV bundles) has a
different exactness class — greedy-consistent under the chunk program, not
bitwise vs the sequential scan — so its tests assert self-consistency
(park/resume invariance, exact emission counts, acceptance bookkeeping)
rather than parity with the scan."""

import functools

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs import get_config
from repro.models import build_bundle
from repro.sessions import (
    LMSessionService,
    SpeculativeDecoder,
    ngram_drafter,
    unpack_column,
    zero_from_column,
)

settings.register_profile("spec", deadline=None, max_examples=8)
settings.load_profile("spec")

V = 64

# one bundle per attention/recurrence family in the zoo: pure-KV rows
# (gqa, mla) verify on the service's own decode_scan program; recurrent
# leaves (rwkv, ssm) verify on the alive-masked scan with value rollback
ARCHS = {
    "gqa": ("olmo-1b", dict(n_layers=2, d_model=32, d_ff=64,
                            vocab_size=V, head_dim=16)),
    "mla": ("deepseek-v2-lite-16b", dict(n_layers=2, d_model=32, d_ff=64,
                                         vocab_size=V)),
    "rwkv": ("rwkv6-1.6b", dict(n_layers=2, d_model=32, d_ff=64,
                                vocab_size=V, rwkv_head_dim=16)),
    "ssm": ("zamba2-1.2b", dict(n_layers=2, d_model=32, d_ff=64,
                                vocab_size=V)),
}


@functools.lru_cache(maxsize=None)
def _setup(arch):
    name, extra = ARCHS[arch]
    cfg = get_config(name).smoke().replace(**extra)
    bundle = build_bundle(cfg)
    return bundle, bundle.init(jax.random.key(0))


@functools.lru_cache(maxsize=None)
def _services(arch):
    """(plain reference, speculative target) service pair per arch, reused
    across tests — sessions are opened/closed per case so jitted programs
    compile once per arch."""
    bundle, params = _setup(arch)
    mk = lambda: LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                                  t_chunk=8, max_sessions=8)
    return mk(), mk()


def _reference(arch, prompt, n):
    """The plain greedy stream — ground truth for every parity assertion."""
    plain, _ = _services(arch)
    sid = plain.open_session(np.asarray(prompt, np.int32))
    try:
        return plain.decode({sid: n})[sid]
    finally:
        plain.close(sid)


def _drafters(prompt, ref):
    """Adversarial drafter zoo, built against the true stream ``ref``."""
    P = len(prompt)

    def right(hist, k):  # oracle: always proposes the true continuation
        i = len(hist) - P
        return np.asarray(ref[i:i + k], np.int32)

    def wrong(hist, k):  # adversary: every proposal is off by one
        i = len(hist) - P
        return np.asarray([(t + 1) % V for t in ref[i:i + k]], np.int32)

    def truncated(hist, k):  # right but returns fewer than asked
        i = len(hist) - P
        return np.asarray(ref[i:i + k][:(k + 1) // 2], np.int32)

    def random(hist, k):
        return np.random.default_rng(len(hist)).integers(
            0, V, size=k).astype(np.int32)

    return {"always-right": right, "always-wrong": wrong,
            "truncated": truncated, "random": random,
            "self-draft": ngram_drafter()}


# ---------------------------------------------------------------------------
# exact (scan) mode: bit-identity for every drafter, every arch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(ARCHS))
def test_speculative_bit_identical_for_every_drafter(arch):
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    want = _reference(arch, prompt, 30)
    _, svc = _services(arch)
    for name, dr in _drafters(prompt, want).items():
        sp = SpeculativeDecoder(svc, dr, k=4)
        sid = svc.open_session(prompt)
        try:
            got = sp.decode({sid: 12})[sid]
            got += sp.decode({sid: 18})[sid]  # split mid-stream
        finally:
            svc.close(sid)
        assert got == want, (arch, name)


def test_acceptance_bookkeeping():
    """Per-lane accept counts: the oracle drafter accepts everything, the
    adversary nothing — and the speedup accounting (dispatch count) shows
    accepted drafts turning into multi-token dispatches."""
    prompt = np.array([7, 9], np.int32)
    want = _reference("gqa", prompt, 24)
    _, svc = _services("gqa")
    drs = _drafters(prompt, want)

    sp = SpeculativeDecoder(svc, drs["always-right"], k=4)
    sid = svc.open_session(prompt)
    d0 = svc.dispatches
    sp.decode({sid: 21})
    right_dispatches = svc.dispatches - d0
    svc.close(sid)
    assert sp.acceptance_rate == 1.0
    assert sp.accepts[sid] == sp.accepted > 0
    # 1 first-token dispatch + ceil(20 / (k+1)) full-acceptance verifies
    assert right_dispatches == 1 + 4

    sp = SpeculativeDecoder(svc, drs["always-wrong"], k=4)
    sid = svc.open_session(prompt)
    d0 = svc.dispatches
    out = sp.decode({sid: 21})[sid]
    svc.close(sid)
    assert out == want[:21]
    assert sp.accepted == 0 and sp.drafted > 0
    # every verify emits exactly 1 token: no faster than plain per-token
    assert svc.dispatches - d0 == 1 + 20


@pytest.mark.parametrize("arch", ["gqa", "rwkv"])
def test_speculative_churn_property(arch):
    """Property: random drafter mixes, random K, random decode splits, and
    random park/evict churn mid-stream never change the emitted stream —
    on both verify-scan families (decode_scan reuse and alive-masked)."""
    @given(st.integers(0, 2**31 - 1))
    def prop(seed):
        rng = np.random.default_rng(seed)
        prompt = rng.integers(0, V, size=int(rng.integers(1, 6))).astype(
            np.int32)
        total = int(rng.integers(8, 28))
        want = _reference(arch, prompt, total)
        _, svc = _services(arch)
        drs = list(_drafters(prompt, want).values())
        sp = SpeculativeDecoder(
            svc, lambda h, k: drs[int(rng.integers(len(drs)))](h, k),
            k=int(rng.integers(1, 6)))
        sid = svc.open_session(prompt)
        other = svc.open_session(np.array([1], np.int32))  # churn pressure
        got = []
        try:
            left = total
            while left:
                n = int(min(rng.integers(1, 9), left))
                got += sp.decode({sid: n})[sid]
                left -= n
                if rng.random() < 0.4:  # evict mid-draft sequence
                    svc.park(sid)
                    svc.decode({other: 2})
        finally:
            svc.close(sid)
            svc.close(other)
        assert got == want
    prop()


@pytest.mark.parametrize("arch", list(ARCHS))
def test_spec_park_resume_through_disk_mid_draft(arch, tmp_path):
    """A session interrupted mid-speculation, spilled to disk, and restored
    into a DIFFERENT service resumes the exact stream — the drafter needs
    no rollback because its input is the host-side token history, which
    travels with the spill meta."""
    prompt = np.array([5, 6], np.int32)
    want = _reference(arch, prompt, 24)
    plain, svc = _services(arch)
    sp = SpeculativeDecoder(svc, ngram_drafter(), k=3)
    sid = svc.open_session(prompt)
    got = sp.decode({sid: 9})[sid]
    path = str(tmp_path / f"spec_{arch}.npz")
    svc.spill_parking(path, include_bound=True)
    assert svc.poll(sid)["state"] == "parked"
    svc.close(sid)

    restored = plain.restore_parking(path)  # "restart" into the other grid
    assert restored == [sid]
    sp2 = SpeculativeDecoder(plain, ngram_drafter(), k=5)  # different K too
    try:
        got += sp2.decode({sid: 15})[sid]
    finally:
        plain.close(sid)
    assert got == want


def test_speculative_retires_at_seq_cap():
    """A draft that would run past seq_cap is clamped; the session retires
    exactly like plain decode (slot freed, outputs kept)."""
    bundle, params = _setup("gqa")
    svc = LMSessionService(bundle, params, n_slots=2, seq_cap=12, t_chunk=8)
    ctl = LMSessionService(bundle, params, n_slots=2, seq_cap=12, t_chunk=8)
    prompt = np.array([1, 2, 3], np.int32)
    c = ctl.open_session(prompt)
    want = ctl.decode({c: 50})[c]
    sp = SpeculativeDecoder(svc, ngram_drafter(), k=4)
    sid = svc.open_session(prompt)
    out = sp.decode({sid: 50})[sid]
    assert out == want and len(out) == 10  # 12 - 3 + 1
    assert svc.poll(sid)["state"] == "done"
    with pytest.raises(RuntimeError):
        sp.decode({sid: 1})


def test_speculative_validation():
    _, svc = _services("gqa")
    with pytest.raises(ValueError):
        SpeculativeDecoder(svc, k=0)
    with pytest.raises(ValueError):
        SpeculativeDecoder(svc, verify="teleport")
    _, rsvc = _services("rwkv")
    with pytest.raises(ValueError, match="parallel verify"):
        SpeculativeDecoder(rsvc, verify="parallel")
    sp = SpeculativeDecoder(svc, k=2)
    with pytest.raises(KeyError):
        sp.decode({12345: 1})
    sid = svc.open_session(np.array([1], np.int32))
    try:
        with pytest.raises(ValueError):
            sp.decode({sid: -1})
        assert sp.decode({sid: 0}) == {sid: []}
    finally:
        svc.close(sid)


# ---------------------------------------------------------------------------
# parallel (throughput) mode: self-consistency, not scan-bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gqa", "mla"])
def test_parallel_verify_self_consistent_across_churn(arch):
    """The parallel chunk mode emits a deterministic stream for a given
    drafter, and evict→park→resume (truncate + zero-extend of the KV
    column) cannot change it: rejected rows past the accepted position are
    masked out of every future attention window."""
    prompt = np.array([2, 7, 1], np.int32)
    _, svc = _services(arch)

    sp = SpeculativeDecoder(svc, ngram_drafter(), k=4, verify="parallel")
    sid = svc.open_session(prompt)
    want = sp.decode({sid: 26})[sid]
    assert len(want) == 26  # exact emission counts, never overshoots
    svc.close(sid)

    sp = SpeculativeDecoder(svc, ngram_drafter(), k=4, verify="parallel")
    sid = svc.open_session(prompt)
    other = svc.open_session(np.array([9], np.int32))
    try:
        got = sp.decode({sid: 7})[sid]
        svc.park(sid)              # mid-draft eviction
        svc.decode({other: 3})     # neighbor stomps the grid
        got += sp.decode({sid: 19})[sid]
    finally:
        svc.close(sid)
        svc.close(other)
    assert got == want


def test_parallel_verify_acceptance_and_cap():
    """Oracle drafts are fully accepted in parallel mode (the verify
    logits ARE the stream source, so self-agreement is exact), and lanes
    too close to seq_cap fall back to the plain scan and retire cleanly."""
    bundle, params = _setup("gqa")
    svc = LMSessionService(bundle, params, n_slots=2, seq_cap=24, t_chunk=8)
    sp = SpeculativeDecoder(svc, ngram_drafter(), k=4, verify="parallel")
    sid = svc.open_session(np.array([4, 2], np.int32))
    first = sp.decode({sid: 8})[sid]

    def oracle(hist, k):  # replay what parallel mode itself generated
        i = len(hist) - 2
        return np.asarray((first + [0] * k)[i:i + k], np.int32)

    sp2 = SpeculativeDecoder(svc, oracle, k=4, verify="parallel")
    # fresh session, same prompt: parallel mode is deterministic
    sid2 = svc.open_session(np.array([4, 2], np.int32))
    out = sp2.decode({sid2: 8})[sid2]
    assert out == first
    assert sp2.acceptance_rate == 1.0
    # run both into the cap: retire exactly like plain decode
    tail = sp.decode({sid: 50})[sid]
    assert len(first + tail) == 24 - 2 + 1
    assert svc.poll(sid)["state"] == "done"
    svc.close(sid2)


def test_zero_from_column_canonicalizes_rejected_rows():
    """state.zero_from_column scrubs the rejected verify tail to exactly
    what a park (O(pos) truncation) + resume (zero-extend) would rebuild —
    the device column becomes canonical in place."""
    prompt = np.array([3, 3, 3], np.int32)
    _, svc = _services("gqa")
    # a drafter that is wrong on purpose guarantees rejected rows
    sp = SpeculativeDecoder(svc, lambda h, k: np.full(k, (h[-1] + 1) % V,
                                                      np.int32),
                            k=4, verify="parallel")
    sid = svc.open_session(prompt)
    try:
        sp.decode({sid: 6})
        assert sp.accepted < sp.drafted  # rejections actually happened
        slot = svc.sched.slot_of[sid]
        steps = svc.sessions[sid].steps
        blob = svc._pack(slot, sid)  # {"kv": column truncated to live pos}
        scrubbed = zero_from_column(svc.cache, svc._batch_axes,
                                    svc._seq_axes, slot, steps)
        rebuilt = unpack_column(svc.cache, svc._batch_axes, slot, blob["kv"])
        for a, b in zip(jax.tree.leaves(scrubbed), jax.tree.leaves(rebuilt)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    finally:
        svc.close(sid)


# ---------------------------------------------------------------------------
# paged slot memory: speculative rollback frees blocks instead of zeroing
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _paged_services(arch):
    """(dense reference, paged speculative target) pair per arch."""
    bundle, params = _setup(arch)
    mk = lambda **kw: LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                                       t_chunk=8, max_sessions=8, **kw)
    return mk(), mk(paged=True)


# gqa verifies on the paged decode_scan itself; ssm (hybrid mamba+attn)
# is the mixed case — pooled KV leaves + recurrent state — and runs the
# paged alive-masked verify scan
@pytest.mark.parametrize("arch", ["gqa", "ssm"])
def test_paged_speculative_bit_identical_and_frees_rejected_blocks(arch):
    """Paged speculative decode emits the dense plain-greedy stream for
    every drafter, and rollback returns rejected-suffix blocks to the pool
    (block count tracks ceil(steps/block_len) after every call)."""
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    want = _reference(arch, prompt, 30)
    _, svc = _paged_services(arch)
    assert svc.paged
    for name, dr in _drafters(prompt, want).items():
        sp = SpeculativeDecoder(svc, dr, k=4)
        sid = svc.open_session(prompt)
        try:
            got = sp.decode({sid: 12})[sid]
            sess = svc.sessions[sid]
            assert len(svc._blocks[sid]) == \
                -(-sess.steps // svc.block_len), (arch, name)
            got += sp.decode({sid: 18})[sid]  # split mid-stream
        finally:
            svc.close(sid)
        assert got == want, (arch, name)
        svc.pool.check()
    assert svc.pool.n_live == len(svc._prefix or ())


def test_paged_parallel_verify_matches_dense_parallel():
    """The paged parallel chunk verify computes the same lane graph on the
    same gathered bytes, so its stream is identical to the DENSE parallel
    mode's for the same drafter (and rejected blocks are trimmed)."""
    prompt = np.array([2, 7, 1], np.int32)
    dense, paged = _paged_services("gqa")
    outs = []
    for svc in (dense, paged):
        sp = SpeculativeDecoder(svc, ngram_drafter(), k=4, verify="parallel")
        sid = svc.open_session(prompt)
        other = svc.open_session(np.array([9], np.int32))
        try:
            got = sp.decode({sid: 7})[sid]
            svc.park(sid)              # mid-draft eviction
            svc.decode({other: 3})     # neighbor stomps the grid
            got += sp.decode({sid: 19})[sid]
        finally:
            svc.close(sid)
            svc.close(other)
        outs.append(got)
        assert len(got) == 26
    assert outs[0] == outs[1]
    paged.pool.check()


def test_paged_spec_spill_restore_mid_draft(tmp_path):
    """A paged session interrupted mid-speculation spills block-granular
    blobs and resumes the exact dense stream in a fresh paged service."""
    prompt = np.array([5, 6], np.int32)
    want = _reference("gqa", prompt, 24)
    bundle, params = _setup("gqa")
    mk = lambda: LMSessionService(bundle, params, n_slots=2, seq_cap=96,
                                  t_chunk=8, max_sessions=8, paged=True)
    svc = mk()
    sp = SpeculativeDecoder(svc, ngram_drafter(), k=3)
    sid = svc.open_session(prompt)
    got = sp.decode({sid: 9})[sid]
    path = str(tmp_path / "paged_spec.npz")
    svc.spill_parking(path, include_bound=True)

    fresh = mk()
    assert fresh.restore_parking(path) == [sid]
    sp2 = SpeculativeDecoder(fresh, ngram_drafter(), k=5)
    try:
        got += sp2.decode({sid: 15})[sid]
    finally:
        fresh.close(sid)
    assert got == want
    fresh.pool.check()
