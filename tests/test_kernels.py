"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)
plus a hypothesis fuzz over random shapes/dilations/dtypes — the parity
ratchet the future real-TPU/GPU-lowering PR must keep passing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels import ref
from repro.kernels.dilated_conv import dilated_causal_conv
from repro.kernels.log2_matmul import log2_matmul
from repro.kernels.proto_extract import proto_extract
from repro.quant.log2 import compute_scale, pack_nibbles, quantize_log2

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


class TestLog2Matmul:
    @pytest.mark.parametrize("M,K,N", [(8, 32, 16), (100, 64, 130),
                                       (256, 128, 512), (1, 256, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, M, K, N, dtype):
        w = jax.random.normal(jax.random.key(M + N), (K, N)) * 0.05
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        x = jax.random.normal(jax.random.key(1), (M, K), dtype)
        out = log2_matmul(x, packed, s, bm=64, bn=64)
        expect = ref.log2_matmul_ref(x, packed, s)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=tol, atol=tol * 10)

    def test_packed_is_half_the_bytes(self):
        """The kernel's raison d'etre: weights cross HBM packed 2/byte."""
        w = jax.random.normal(jax.random.key(0), (128, 256)) * 0.1
        packed = pack_nibbles(quantize_log2(w, compute_scale(w)))
        assert packed.size * packed.dtype.itemsize == w.size // 2


class TestDilatedConv:
    @pytest.mark.parametrize("B,T,Cin,Cout,K,d", [
        (2, 37, 4, 8, 3, 1), (3, 128, 16, 32, 7, 4),
        (1, 200, 8, 100, 2, 16), (2, 64, 28, 24, 3, 2)])
    def test_vs_oracle(self, B, T, Cin, Cout, K, d):
        x = jax.random.normal(jax.random.key(0), (B, T, Cin))
        w = jax.random.normal(jax.random.key(1), (K, Cin, Cout)) * 0.2
        b = jax.random.normal(jax.random.key(2), (Cout,)) * 0.1
        out = dilated_causal_conv(x, w, b, d, bco=32)
        expect = ref.dilated_conv_ref(x, w, b, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Future inputs cannot affect past outputs."""
        B, T, C, K, d = 1, 32, 4, 3, 2
        x = jax.random.normal(jax.random.key(0), (B, T, C))
        w = jax.random.normal(jax.random.key(1), (K, C, C)) * 0.3
        b = jnp.zeros((C,))
        y1 = dilated_causal_conv(x, w, b, d)
        x2 = x.at[:, 20:].set(123.0)
        y2 = dilated_causal_conv(x2, w, b, d)
        np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                                   rtol=1e-5)


class TestKernelFuzz:
    """Property fuzz: every drawn (shape, dilation, dtype, block-size)
    combination must match the oracle.  One drawn seed drives all the
    randomness so failures shrink to a single reproducible integer."""

    @given(st.integers(0, 2**31 - 1))
    def test_log2_matmul_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        M = int(rng.integers(1, 160))
        K = int(rng.integers(8, 192))
        N = 2 * int(rng.integers(4, 128))  # nibble packing needs even N
        dtype = jnp.float32 if rng.integers(2) else jnp.bfloat16
        bm, bn = int(rng.choice([16, 32, 64, 128])), int(rng.choice([16, 32, 64]))
        w = jax.random.normal(jax.random.key(seed % 997), (K, N)) * 0.05
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        x = jax.random.normal(jax.random.key(seed % 991), (M, K), dtype)
        out = log2_matmul(x, packed, s, bm=bm, bn=bn)
        expect = ref.log2_matmul_ref(x, packed, s)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=tol, atol=tol * 10)

    @given(st.integers(0, 2**31 - 1))
    def test_dilated_conv_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        B = int(rng.integers(1, 4))
        Cin = int(rng.integers(1, 32))
        Cout = int(rng.integers(1, 64))
        K = int(rng.integers(2, 8))
        d = int(rng.choice([1, 2, 4, 8, 16]))
        T = int(rng.integers((K - 1) * d + 1, (K - 1) * d + 96))
        bco = int(rng.choice([16, 32, 64]))
        x = jax.random.normal(jax.random.key(seed % 997), (B, T, Cin))
        w = jax.random.normal(jax.random.key(seed % 991), (K, Cin, Cout)) * 0.2
        b = jax.random.normal(jax.random.key(seed % 983), (Cout,)) * 0.1
        out = dilated_causal_conv(x, w, b, d, bco=bco)
        expect = ref.dilated_conv_ref(x, w, b, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


class TestProtoExtract:
    @pytest.mark.parametrize("N,k,V", [(5, 1, 64), (20, 5, 64), (250, 10, 32),
                                       (3, 7, 128)])
    def test_vs_oracle(self, N, k, V):
        emb = jax.random.normal(jax.random.key(N), (N * k, V))
        onehot = jax.nn.one_hot(jnp.repeat(jnp.arange(N), k), N).T
        W, b = proto_extract(emb, onehot, k, bn=64)
        Wr, br = ref.proto_extract_ref(emb, onehot, k)
        np.testing.assert_allclose(np.asarray(W), np.asarray(Wr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(b), np.asarray(br),
                                   rtol=1e-4, atol=1e-4)

    def test_matches_protonet_module(self):
        """Kernel output == core/protonet Eq. 6 (modulo the bias sign
        convention: the kernel returns -(1/2k)||s||^2 directly)."""
        from repro.core import protonet as pn
        N, k, V = 6, 4, 32
        emb = jax.random.normal(jax.random.key(0), (N * k, V))
        labels = jnp.repeat(jnp.arange(N), k)
        onehot = jax.nn.one_hot(labels, N).T
        Wk, bk = proto_extract(emb, onehot, k)
        s = pn.support_sums(emb, labels, N)
        Wp, bp = pn.pn_fc_from_sums(s, k)
        np.testing.assert_allclose(np.asarray(Wk), np.asarray(Wp), atol=1e-4)
        np.testing.assert_allclose(np.asarray(bk), np.asarray(bp),
                                   rtol=1e-4, atol=1e-4)
