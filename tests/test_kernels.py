"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)
plus a hypothesis fuzz over random shapes/dilations/dtypes — the parity
ratchet the real-TPU/GPU-lowering path must keep passing — and the fused
TCN block kernel's bit-parity contract (jnp fast path AND pallas interpret
vs the per-position ref oracle, across every chameleon_tcn dilation and a
chunk-size sweep).  Backend selection itself (kernels/dispatch.py) is
covered at the bottom: resolve-once semantics, env override, registry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels import dispatch, ref
from repro.kernels.dilated_conv import dilated_causal_conv
from repro.kernels.log2_matmul import log2_matmul
from repro.kernels.ops import (
    make_dilated_conv_op,
    make_log2_matmul_op,
    make_proto_extract_op,
)
from repro.kernels.proto_extract import proto_extract
from repro.kernels.tcn_block import (
    expand_weight,
    make_block_fn,
    tcn_block_fused,
    tcn_block_pallas,
)
from repro.quant.log2 import compute_scale, pack_nibbles, quantize_log2

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


class TestLog2Matmul:
    @pytest.mark.parametrize("M,K,N", [(8, 32, 16), (100, 64, 130),
                                       (256, 128, 512), (1, 256, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, M, K, N, dtype):
        w = jax.random.normal(jax.random.key(M + N), (K, N)) * 0.05
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        x = jax.random.normal(jax.random.key(1), (M, K), dtype)
        out = log2_matmul(x, packed, s, bm=64, bn=64, interpret=True)
        expect = ref.log2_matmul_ref(x, packed, s)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=tol, atol=tol * 10)

    def test_packed_is_half_the_bytes(self):
        """The kernel's raison d'etre: weights cross HBM packed 2/byte."""
        w = jax.random.normal(jax.random.key(0), (128, 256)) * 0.1
        packed = pack_nibbles(quantize_log2(w, compute_scale(w)))
        assert packed.size * packed.dtype.itemsize == w.size // 2


class TestDilatedConv:
    @pytest.mark.parametrize("B,T,Cin,Cout,K,d", [
        (2, 37, 4, 8, 3, 1), (3, 128, 16, 32, 7, 4),
        (1, 200, 8, 100, 2, 16), (2, 64, 28, 24, 3, 2)])
    def test_vs_oracle(self, B, T, Cin, Cout, K, d):
        x = jax.random.normal(jax.random.key(0), (B, T, Cin))
        w = jax.random.normal(jax.random.key(1), (K, Cin, Cout)) * 0.2
        b = jax.random.normal(jax.random.key(2), (Cout,)) * 0.1
        out = dilated_causal_conv(x, w, b, d, bco=32, interpret=True)
        expect = ref.dilated_conv_ref(x, w, b, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Future inputs cannot affect past outputs."""
        B, T, C, K, d = 1, 32, 4, 3, 2
        x = jax.random.normal(jax.random.key(0), (B, T, C))
        w = jax.random.normal(jax.random.key(1), (K, C, C)) * 0.3
        b = jnp.zeros((C,))
        y1 = dilated_causal_conv(x, w, b, d, interpret=True)
        x2 = x.at[:, 20:].set(123.0)
        y2 = dilated_causal_conv(x2, w, b, d, interpret=True)
        np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                                   rtol=1e-5)


class TestKernelFuzz:
    """Property fuzz: every drawn (shape, dilation, dtype, block-size)
    combination must match the oracle.  One drawn seed drives all the
    randomness so failures shrink to a single reproducible integer."""

    @given(st.integers(0, 2**31 - 1))
    def test_log2_matmul_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        M = int(rng.integers(1, 160))
        K = int(rng.integers(8, 192))
        N = 2 * int(rng.integers(4, 128))  # nibble packing needs even N
        dtype = jnp.float32 if rng.integers(2) else jnp.bfloat16
        bm, bn = int(rng.choice([16, 32, 64, 128])), int(rng.choice([16, 32, 64]))
        w = jax.random.normal(jax.random.key(seed % 997), (K, N)) * 0.05
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        x = jax.random.normal(jax.random.key(seed % 991), (M, K), dtype)
        out = log2_matmul(x, packed, s, bm=bm, bn=bn, interpret=True)
        expect = ref.log2_matmul_ref(x, packed, s)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=tol, atol=tol * 10)

    @given(st.integers(0, 2**31 - 1))
    def test_dilated_conv_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        B = int(rng.integers(1, 4))
        Cin = int(rng.integers(1, 32))
        Cout = int(rng.integers(1, 64))
        K = int(rng.integers(2, 8))
        d = int(rng.choice([1, 2, 4, 8, 16]))
        T = int(rng.integers((K - 1) * d + 1, (K - 1) * d + 96))
        bco = int(rng.choice([16, 32, 64]))
        x = jax.random.normal(jax.random.key(seed % 997), (B, T, Cin))
        w = jax.random.normal(jax.random.key(seed % 991), (K, Cin, Cout)) * 0.2
        b = jax.random.normal(jax.random.key(seed % 983), (Cout,)) * 0.1
        out = dilated_causal_conv(x, w, b, d, bco=bco, interpret=True)
        expect = ref.dilated_conv_ref(x, w, b, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


class TestProtoExtract:
    @pytest.mark.parametrize("N,k,V", [(5, 1, 64), (20, 5, 64), (250, 10, 32),
                                       (3, 7, 128)])
    def test_vs_oracle(self, N, k, V):
        emb = jax.random.normal(jax.random.key(N), (N * k, V))
        onehot = jax.nn.one_hot(jnp.repeat(jnp.arange(N), k), N).T
        W, b = proto_extract(emb, onehot, k, bn=64, interpret=True)
        Wr, br = ref.proto_extract_ref(emb, onehot, k)
        np.testing.assert_allclose(np.asarray(W), np.asarray(Wr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(b), np.asarray(br),
                                   rtol=1e-4, atol=1e-4)

    def test_matches_protonet_module(self):
        """Kernel output == core/protonet Eq. 6 (modulo the bias sign
        convention: the kernel returns -(1/2k)||s||^2 directly)."""
        from repro.core import protonet as pn
        N, k, V = 6, 4, 32
        emb = jax.random.normal(jax.random.key(0), (N * k, V))
        labels = jnp.repeat(jnp.arange(N), k)
        onehot = jax.nn.one_hot(labels, N).T
        Wk, bk = proto_extract(emb, onehot, k, interpret=True)
        s = pn.support_sums(emb, labels, N)
        Wp, bp = pn.pn_fc_from_sums(s, k)
        np.testing.assert_allclose(np.asarray(Wk), np.asarray(Wp), atol=1e-4)
        np.testing.assert_allclose(np.asarray(bk), np.asarray(bp),
                                   rtol=1e-4, atol=1e-4)

    def test_adapt_kernel_path_matches_jnp_path(self):
        """core/protonet.adapt through the dispatch layer: the kernel path
        (interpret) agrees with the segment-sum path it replaces."""
        from repro.core.protonet import adapt
        N, k, V = 4, 3, 16
        emb = jax.random.normal(jax.random.key(3), (N * k, V))
        labels = jnp.repeat(jnp.arange(N), k)
        embed_fn = lambda params, batch: emb
        w_ref_, b_ref_ = adapt(embed_fn, None, None, labels, N, k,
                               backend="ref")
        w_k, b_k = adapt(embed_fn, None, None, labels, N, k,
                         backend="interpret")
        np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref_),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(b_k), np.asarray(b_ref_),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused TCN block: the streaming hot-loop kernel
# ---------------------------------------------------------------------------

def _block_inputs(seed, S, T, Cin, C, k, d, *, quantize, with_down):
    """Random strips + a baked-layout weight dict for one block."""
    rng = np.random.default_rng(seed)
    n = (k - 1) * d
    strip1 = jnp.asarray(rng.normal(size=(S, n + T, Cin)).astype(np.float32))
    hist2 = jnp.asarray(rng.normal(size=(S, n, C)).astype(np.float32))

    def mk_w(shape, key):
        w = jax.random.normal(jax.random.key(key), shape) * 0.2
        if not quantize:
            return w, w
        s = compute_scale(w)
        q = quantize_log2(w, s)
        from repro.quant.log2 import dequantize_log2
        return dequantize_log2(q, s), {"codes": pack_nibbles(q), "scale": s}

    w1x, w1 = mk_w((k, Cin, C), seed + 1)
    w2x, w2 = mk_w((k, C, C), seed + 2)
    p = {"conv1_w": w1,
         "conv1_b": jax.random.normal(jax.random.key(seed + 3), (C,)) * 0.1,
         "conv2_w": w2,
         "conv2_b": jax.random.normal(jax.random.key(seed + 4), (C,)) * 0.1}
    expanded = [w1x, w2x, None]
    if with_down:
        dwx, dw = mk_w((1, Cin, C), seed + 5)
        p["down_w"] = dw
        p["down_b"] = jax.random.normal(jax.random.key(seed + 6), (C,)) * 0.1
        expanded[2] = dwx
    return strip1, hist2, p, expanded


CHAMELEON_DILATIONS = [2 ** i for i in range(7)]  # the 7-block FSL preset


class TestTCNBlockFused:
    """Bit-parity contract of kernels/tcn_block.py: the fused batched-jnp
    fast path and the pallas kernel (interpret) against the per-position
    ref oracle — across every chameleon_tcn dilation and a chunk-size
    sweep, fp32 and nibble-packed log2."""

    @pytest.mark.parametrize("d", CHAMELEON_DILATIONS)
    @pytest.mark.parametrize("T", [1, 7, 32, 160])
    def test_fused_vs_oracle_all_dilations_and_chunks(self, d, T):
        k, Cin, C = 7, 1, 8  # chameleon kernel size; slim channels for speed
        strip1, hist2, p, (w1, w2, dw) = _block_inputs(
            d * 1000 + T, 2, T, Cin, C, k, d, quantize=False, with_down=True)
        h, mid = jax.jit(lambda a, b, p: tcn_block_fused(
            a, b, p, dilation=d, k=k))(strip1, hist2, p)
        hr, mr = ref.tcn_block_ref(strip1, hist2, w1, p["conv1_b"], w2,
                                   p["conv2_b"], dw, p["down_b"],
                                   dilation=d, k=k)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        np.testing.assert_array_equal(np.asarray(mid), np.asarray(mr))

    @pytest.mark.parametrize("d", [1, 8, 64])
    @pytest.mark.parametrize("quantize", [False, True])
    def test_pallas_interpret_vs_oracle(self, d, quantize):
        k, T, Cin, C = 7, 24, 4, 8
        strip1, hist2, p, (w1, w2, dw) = _block_inputs(
            d + 17, 2, T, Cin, C, k, d, quantize=quantize, with_down=True)
        h, mid = jax.jit(lambda a, b, p: tcn_block_pallas(
            a, b, p, dilation=d, k=k, quantize=quantize,
            interpret=True))(strip1, hist2, p)
        hr, mr = ref.tcn_block_ref(strip1, hist2, w1, p["conv1_b"], w2,
                                   p["conv2_b"], dw, p["down_b"],
                                   dilation=d, k=k, quantize=quantize)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        np.testing.assert_array_equal(np.asarray(mid), np.asarray(mr))

    @pytest.mark.parametrize("with_down", [False, True])
    def test_quantized_packed_weights_expand_in_kernel(self, with_down):
        """Packed codes (2/byte at rest) expand to the exact baked wq."""
        k, d, T, Cin, C = 3, 2, 12, 8, 8
        strip1, hist2, p, (w1, w2, dw) = _block_inputs(
            5, 2, T, Cin, C, k, d, quantize=True, with_down=with_down)
        assert p["conv1_w"]["codes"].dtype == jnp.uint8
        assert p["conv1_w"]["codes"].shape[-1] == C // 2
        np.testing.assert_array_equal(np.asarray(expand_weight(p["conv1_w"])),
                                      np.asarray(w1))
        h, mid = jax.jit(lambda a, b, p: tcn_block_fused(
            a, b, p, dilation=d, k=k, quantize=True))(strip1, hist2, p)
        db = p["down_b"] if with_down else None
        hr, mr = ref.tcn_block_ref(strip1, hist2, w1, p["conv1_b"], w2,
                                   p["conv2_b"], dw, db, dilation=d, k=k,
                                   quantize=True)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        np.testing.assert_array_equal(np.asarray(mid), np.asarray(mr))

    @given(st.integers(0, 2**31 - 1))
    def test_fused_block_random_shapes(self, seed):
        """Fuzz ratchet for the fused block: any (k, d, T, channels,
        quantize, residual) draw must match the oracle bit for bit."""
        rng = np.random.default_rng(seed)
        k = int(rng.integers(2, 8))
        d = int(rng.choice([1, 2, 4, 8, 16]))
        T = int(rng.integers(1, 48))
        Cin = 2 * int(rng.integers(1, 9))
        C = 2 * int(rng.integers(1, 9))
        quantize = bool(rng.integers(2))
        with_down = bool(rng.integers(2)) or Cin != C
        strip1, hist2, p, (w1, w2, dw) = _block_inputs(
            seed % 100003, 2, T, Cin, C, k, d, quantize=quantize,
            with_down=with_down)
        h, mid = jax.jit(lambda a, b, p: tcn_block_fused(
            a, b, p, dilation=d, k=k, quantize=quantize))(strip1, hist2, p)
        db = p["down_b"] if with_down else None
        hr, mr = ref.tcn_block_ref(strip1, hist2, w1, p["conv1_b"], w2,
                                   p["conv2_b"], dw, db, dilation=d, k=k,
                                   quantize=quantize)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        np.testing.assert_array_equal(np.asarray(mid), np.asarray(mr))


# ---------------------------------------------------------------------------
# Backend dispatch: resolve-once semantics
# ---------------------------------------------------------------------------

class TestDispatch:
    def test_auto_resolves_to_ref_on_cpu(self):
        r = dispatch.resolve("auto")
        assert r.backend == "ref" and not r.use_pallas and not r.interpret

    def test_explicit_backends(self):
        assert dispatch.resolve("interpret").interpret
        assert dispatch.resolve("mosaic").use_pallas
        assert not dispatch.resolve("mosaic").interpret
        assert dispatch.resolve(None).backend == dispatch.resolve("auto").backend

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, "interpret")
        assert dispatch.resolve("auto").backend == "interpret"
        # explicit requests beat the env override
        assert dispatch.resolve("ref").backend == "ref"

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            dispatch.resolve("cuda13")
        with pytest.raises(KeyError):
            dispatch.build("not_an_op")

    def test_registry_covers_all_ops(self):
        assert {"dilated_conv", "log2_matmul", "proto_extract",
                "tcn_block"} <= set(dispatch.registered_ops())

    def test_ops_resolve_once_and_agree(self):
        """Every registered op built as 'interpret' matches its 'ref'
        build — the dispatch table is consistent, not just populated."""
        x = jax.random.normal(jax.random.key(0), (5, 16))
        w = jax.random.normal(jax.random.key(1), (16, 8)) * 0.1
        s = compute_scale(w)
        packed = pack_nibbles(quantize_log2(w, s))
        a = make_log2_matmul_op("ref")(x, packed, s)
        b = make_log2_matmul_op("interpret")(x, packed, s)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
        cw = jax.random.normal(jax.random.key(2), (3, 4, 8)) * 0.2
        cb = jnp.zeros((8,))
        cx = jax.random.normal(jax.random.key(3), (2, 20, 4))
        a = make_dilated_conv_op("ref")(cx, cw, cb, 2)
        b = make_dilated_conv_op("interpret")(cx, cw, cb, 2)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        emb = jax.random.normal(jax.random.key(4), (12, 8))
        onehot = jax.nn.one_hot(jnp.repeat(jnp.arange(4), 3), 4).T
        (wa, ba) = make_proto_extract_op("ref")(emb, onehot, 3)
        (wb, bb) = make_proto_extract_op("interpret")(emb, onehot, 3)
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wb),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ba), np.asarray(bb),
                                   rtol=1e-5, atol=1e-5)

    def test_archconfig_kernel_backend_reaches_dispatch(self, monkeypatch):
        """cfg.kernel_backend is honored by the fused-path constructors
        (backend=None defers to the config, not straight to platform)."""
        from repro.configs import get_config
        from repro.core.streaming import make_fused_chunk
        from repro.models.tcn import make_fused_forward
        calls = []
        orig = dispatch.build

        def spy(op, backend=None):
            calls.append(backend)
            return orig(op, backend)

        monkeypatch.setattr(dispatch, "build", spy)
        cfg = get_config("chameleon-tcn").smoke().replace(
            kernel_backend="interpret")
        make_fused_chunk(cfg)
        make_fused_forward(cfg)
        assert calls == ["interpret", "interpret"]
        make_fused_chunk(cfg, backend="ref")  # explicit beats the config
        assert calls[-1] == "ref"

    def test_block_fn_backend_parity(self):
        """make_block_fn('ref') and ('interpret') are bit-identical on the
        same strips — the fused op dispatches without changing bits."""
        strip1, hist2, p, _ = _block_inputs(9, 2, 10, 4, 8, 3, 2,
                                            quantize=False, with_down=True)
        fr = make_block_fn("ref")
        fi = make_block_fn("interpret")
        hr, mr = fr(strip1, hist2, p, dilation=2, k=3)
        hi, mi = fi(strip1, hist2, p, dilation=2, k=3)
        np.testing.assert_array_equal(np.asarray(hr), np.asarray(hi))
        np.testing.assert_array_equal(np.asarray(mr), np.asarray(mi))
