"""Multi-DEVICE placement of the sharded session subsystem.

Tier-1 exercises ``grid_pspecs``/``bank_pspecs`` only on 1-device meshes
(everything degenerates to replicated).  These tests force a 4-device host
platform in a subprocess (the test_sharding.py idiom — device count is
locked at first jax init, so the main pytest process must keep its single
CPU device) and assert the specs actually PLACE shards:

  * slot-grid leaves split 4-ways over ``data`` (2 slots per device);
  * tenant-bank leaves split 4-ways over ``model``;
  * a chunked ``push_audio`` on the 4-device mesh is bit-identical to the
    unsharded service (cross-device chunk parity);
  * the LM slot grid (``column_pspecs``: per-leaf session axes, NOT
    leading) splits 4-ways over ``data``, chunk-prefills and decodes
    bit-identically to the unsharded service, and STAYS sharded through
    ``decode_scan`` dispatches.

CI runs this file as the dedicated ``multidevice`` job.
"""

import os
import subprocess
import sys

SUBPROC = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import StreamSessionService, bank_init, bank_pspecs

assert jax.device_count() == 4, jax.devices()

cfg = get_config("chameleon-tcn").replace(
    tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
    embed_dim=12, n_classes=4)
bundle = build_bundle(cfg)
params = bundle.init(jax.random.key(0))
bn = tcn_empty_state(cfg)

# -- slot shards land on all 4 devices ------------------------------------
mesh = make_mesh((4, 1), ("data", "model"))
svc = StreamSessionService(bundle, params, bn, n_slots=8, max_tenants=4,
                           t_chunk=8, mesh=mesh)
for leaf in jax.tree.leaves(svc.states):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, (leaf.shape, devs)
    for s in leaf.addressable_shards:  # 8 slots / 4 devices = 2 per shard
        assert s.data.shape[0] == 2, (leaf.shape, s.data.shape)
print("grid: 8 slots -> 4 devices x 2-slot shards")

# -- tenant-bank shards land on all 4 devices -----------------------------
mesh_m = make_mesh((1, 4), ("data", "model"))
bank = bank_init(8, 4, cfg.embed_dim)
bank = jax.device_put(bank, jax.tree.map(
    lambda p: jax.sharding.NamedSharding(mesh_m, p),
    bank_pspecs(bank, mesh_m)))
for leaf in jax.tree.leaves(bank):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, (leaf.shape, devs)
    for s in leaf.addressable_shards:  # 8 tenants / 4 devices
        assert s.data.shape[0] == 2, (leaf.shape, s.data.shape)
print("bank: 8 tenants -> 4 devices x 2-tenant shards")

# -- cross-device chunked push is bit-identical to unsharded --------------
plain = StreamSessionService(bundle, params, bn, n_slots=8, max_tenants=4,
                             t_chunk=8)
x = np.random.default_rng(0).normal(size=(8, 21, 2)).astype(np.float32)
sids = [svc.open_session() for _ in range(8)]
pids = [plain.open_session() for _ in range(8)]
ra = svc.push_audio({sid: x[i] for i, sid in enumerate(sids)})
rb = plain.push_audio({pid: x[i] for i, pid in enumerate(pids)})
for i in range(8):
    np.testing.assert_array_equal(ra[sids[i]]["emb"], rb[pids[i]]["emb"])
    np.testing.assert_array_equal(ra[sids[i]]["logits"], rb[pids[i]]["logits"])
for leaf in jax.tree.leaves(svc.states):  # states STAY sharded after a push
    assert len({s.device for s in leaf.addressable_shards}) == 4
print("push: 4-device chunked scan bit-identical to unsharded")

# -- LM slot grid: per-leaf session axes shard over data -------------------
from repro.sessions import LMSessionService

lcfg = get_config("olmo-1b").smoke().replace(
    n_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=16)
lbundle = build_bundle(lcfg)
lparams = lbundle.init(jax.random.key(1))
lsvc = LMSessionService(lbundle, lparams, n_slots=8, seq_cap=48, t_chunk=8,
                        mesh=mesh)
lplain = LMSessionService(lbundle, lparams, n_slots=8, seq_cap=48, t_chunk=8)
baxes = jax.tree.leaves(lsvc._batch_axes)
for leaf, bax in zip(jax.tree.leaves(lsvc.cache), baxes):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, (leaf.shape, devs)
    for s in leaf.addressable_shards:  # 8 sessions / 4 devices per leaf
        assert s.data.shape[bax] == 2, (leaf.shape, bax, s.data.shape)
print("lm grid: 8 sessions -> 4 devices x 2-session shards (per-leaf axes)")

rng = np.random.default_rng(7)
prompts = [rng.integers(0, 64, size=rng.integers(1, 9)).astype(np.int32)
           for _ in range(8)]
lsids = [lsvc.open_session(p) for p in prompts]   # chunk-prefills sharded
psids = [lplain.open_session(p) for p in prompts]
for _ in range(2):  # two waves: greedy feedback crosses dispatches too
    ra = lsvc.decode({sid: 8 for sid in lsids})
    rb = lplain.decode({sid: 8 for sid in psids})
    for a, b in zip(lsids, psids):
        assert ra[a] == rb[b], (ra[a], rb[b])
for leaf, bax in zip(jax.tree.leaves(lsvc.cache), baxes):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, "cache lost its sharding across decode_scan"
    for s in leaf.addressable_shards:
        assert s.data.shape[bax] == 2
print("lm decode: 4-device decode_scan bit-identical to unsharded, "
      "placement preserved")
print("MULTIDEVICE_OK")
'''


def test_four_device_slot_and_bank_placement():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTIDEVICE_OK" in out.stdout, out.stdout
