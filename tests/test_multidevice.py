"""Multi-DEVICE placement of the sharded session subsystem.

Tier-1 exercises ``grid_pspecs``/``bank_pspecs`` only on 1-device meshes
(everything degenerates to replicated).  These tests force a 4-device host
platform in a subprocess (the test_sharding.py idiom — device count is
locked at first jax init, so the main pytest process must keep its single
CPU device) and assert the specs actually PLACE shards:

  * slot-grid leaves split 4-ways over ``data`` (2 slots per device);
  * tenant-bank leaves split 4-ways over ``model``;
  * a chunked ``push_audio`` on the 4-device mesh is bit-identical to the
    unsharded service (cross-device chunk parity).

CI runs this file as the dedicated ``multidevice`` job.
"""

import os
import subprocess
import sys

SUBPROC = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_bundle
from repro.models.tcn import tcn_empty_state
from repro.sessions import StreamSessionService, bank_init, bank_pspecs

assert jax.device_count() == 4, jax.devices()

cfg = get_config("chameleon-tcn").replace(
    tcn_channels=(8, 8), tcn_kernel=3, tcn_in_channels=2,
    embed_dim=12, n_classes=4)
bundle = build_bundle(cfg)
params = bundle.init(jax.random.key(0))
bn = tcn_empty_state(cfg)

# -- slot shards land on all 4 devices ------------------------------------
mesh = make_mesh((4, 1), ("data", "model"))
svc = StreamSessionService(bundle, params, bn, n_slots=8, max_tenants=4,
                           t_chunk=8, mesh=mesh)
for leaf in jax.tree.leaves(svc.states):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, (leaf.shape, devs)
    for s in leaf.addressable_shards:  # 8 slots / 4 devices = 2 per shard
        assert s.data.shape[0] == 2, (leaf.shape, s.data.shape)
print("grid: 8 slots -> 4 devices x 2-slot shards")

# -- tenant-bank shards land on all 4 devices -----------------------------
mesh_m = make_mesh((1, 4), ("data", "model"))
bank = bank_init(8, 4, cfg.embed_dim)
bank = jax.device_put(bank, jax.tree.map(
    lambda p: jax.sharding.NamedSharding(mesh_m, p),
    bank_pspecs(bank, mesh_m)))
for leaf in jax.tree.leaves(bank):
    devs = {s.device for s in leaf.addressable_shards}
    assert len(devs) == 4, (leaf.shape, devs)
    for s in leaf.addressable_shards:  # 8 tenants / 4 devices
        assert s.data.shape[0] == 2, (leaf.shape, s.data.shape)
print("bank: 8 tenants -> 4 devices x 2-tenant shards")

# -- cross-device chunked push is bit-identical to unsharded --------------
plain = StreamSessionService(bundle, params, bn, n_slots=8, max_tenants=4,
                             t_chunk=8)
x = np.random.default_rng(0).normal(size=(8, 21, 2)).astype(np.float32)
sids = [svc.open_session() for _ in range(8)]
pids = [plain.open_session() for _ in range(8)]
ra = svc.push_audio({sid: x[i] for i, sid in enumerate(sids)})
rb = plain.push_audio({pid: x[i] for i, pid in enumerate(pids)})
for i in range(8):
    np.testing.assert_array_equal(ra[sids[i]]["emb"], rb[pids[i]]["emb"])
    np.testing.assert_array_equal(ra[sids[i]]["logits"], rb[pids[i]]["logits"])
for leaf in jax.tree.leaves(svc.states):  # states STAY sharded after a push
    assert len({s.device for s in leaf.addressable_shards}) == 4
print("push: 4-device chunked scan bit-identical to unsharded")
print("MULTIDEVICE_OK")
'''


def test_four_device_slot_and_bank_placement():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTIDEVICE_OK" in out.stdout, out.stdout
